"""Shuffle benchmark: num_buckets × skew sweep on fat-tree and torus,
static ECMP vs queue-feedback routing.

For each (topology, bucket count, skew) cell the word-count shuffle
program is compiled twice — once stopping at the static route-count ECMP
tie-break (``STATIC_ECMP_PASSES``) and once through the full pipeline
whose ``reroute-feedback`` pass re-routes on the streaming simulator's
*measured* per-switch queueing — and both plans run through the
per-packet simulator: streamed makespan, queueing delay, per-bucket wire
bytes and the hottest switch's reducer-state residency. The
static-vs-feedback makespan pair is the headline: feedback routing must
never lose, and wins where skewed buckets collide on fat-tree links.
Writes a BENCH_shuffle.json artifact; CI's bench-smoke job fails if any
simulated metric regresses >10% against the committed baseline
(``benchmarks/check_regression.py``).

    PYTHONPATH=src:. python benchmarks/run.py shuffle
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro import compiler, shuffle
from repro.core import topology, wordcount

from benchmarks._provenance import write_bench

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                        "BENCH_shuffle.json")

VOCAB = 256
N_MAPPERS = 8
BUCKETS = (2, 4, 8, 16)
SKEWS = (0.0, 1.0, 2.0)  # zipf-ish exponent over bucket ranks


def _weights(num_buckets: int, skew: float) -> tuple[float, ...] | None:
    if skew == 0.0:
        return None
    return tuple(1.0 / (b + 1) ** skew for b in range(num_buckets))


def _topologies():
    ft = topology.fat_tree_topology(4)
    yield "fat_tree_k4", ft, [f"h{i}" for i in range(N_MAPPERS)], f"h{len(ft.hosts) - 1}"
    torus = topology.TorusTopology(dims=(4, 4))
    yield "torus_4x4", torus, [f"d{2 * i}" for i in range(N_MAPPERS)], "d15"


def case_inputs(num_buckets: int, skew: float) -> dict:
    """Deterministic per-cell mapper histograms (shared with
    bench_autotune so the two BENCH jsons stay cell-comparable)."""
    rs = np.random.RandomState(num_buckets * 7 + int(skew * 3))
    return {
        f"s{i}": rs.randint(0, 50, size=(VOCAB,)).astype(np.float64)
        for i in range(N_MAPPERS)
    }


def _case(topo_name, topo, hosts, sink, num_buckets, skew) -> dict:
    prog = wordcount.wordcount_shuffle_program(
        N_MAPPERS, VOCAB, num_buckets=num_buckets,
        weights=_weights(num_buckets, skew), hosts=hosts, sink_host=sink,
    )
    static = compiler.compile(prog, topo, passes=compiler.STATIC_ECMP_PASSES)
    t0 = time.perf_counter()
    plan = compiler.compile(prog, topo)  # full pipeline incl. reroute-feedback
    compile_us = (time.perf_counter() - t0) * 1e6
    inputs = case_inputs(num_buckets, skew)
    sim = plan.simulate(inputs)
    sim_static = static.simulate_timing()
    stats = shuffle.plan_shuffle(plan)
    ref = np.sum([inputs[f"s{i}"] for i in range(N_MAPPERS)], axis=0)
    np.testing.assert_array_equal(sim.outputs["OUT"], ref)  # shuffle is exact
    r = sim.report
    return {
        "topology": topo_name,
        "num_buckets": num_buckets,
        "skew": skew,
        "compile_us": round(compile_us, 1),
        # feedback-routed (the emitted plan) vs static-ECMP streamed timing
        "sim_time_us": round(r.time_s * 1e6, 3),
        "sim_time_us_static": round(sim_static.time_s * 1e6, 3),
        "makespan_ticks": r.makespan_ticks,
        "makespan_ticks_static": sim_static.makespan_ticks,
        "queue_delay_ticks": r.queue_delay_ticks,
        "queue_delay_ticks_static": sim_static.queue_delay_ticks,
        "feedback_rounds": (plan.feedback or {}).get("rounds", 0),
        "queued_switches": len(r.queued_batches),
        "wire_bytes": round(r.wire_bytes, 1),
        "bucket_wire_bytes": {str(k): round(v, 1) for k, v in stats.bucket_wire_bytes.items()},
        "hot_bucket": stats.hot_bucket,
        "max_switch_residency_bytes": stats.max_switch_residency_bytes,
        "reducer_switches": len(stats.residency_by_switch),
    }


def run() -> list[tuple[str, float, str]]:
    records = []
    for topo_name, topo, hosts, sink in _topologies():
        for b in BUCKETS:
            for skew in SKEWS:
                records.append(_case(topo_name, topo, hosts, sink, b, skew))

    write_bench(OUT_PATH, records)

    rows = []
    for r in records:
        gain = r["makespan_ticks_static"] - r["makespan_ticks"]
        pct = 100.0 * gain / max(r["makespan_ticks_static"], 1)
        rows.append((
            f"shuffle.{r['topology']}.b{r['num_buckets']}.skew{r['skew']}",
            r["sim_time_us"],
            f"static={r['makespan_ticks_static']}t feedback={r['makespan_ticks']}t "
            f"({pct:+.1f}%) queue={r['queue_delay_ticks']}t "
            f"hot_bucket={r['hot_bucket']} "
            f"residency_max={r['max_switch_residency_bytes']}B",
        ))
    rows.append(("shuffle.artifact", 0.0, f"wrote {os.path.basename(OUT_PATH)}"))
    return rows
