"""§3 / Eq (1): serialization cost model.

Rows: N (time slices) → sustainable ingest from the discrete simulator vs
the closed form C/(1+1/N)^N, converging to C/e = 367.88 Mbps for GbE —
the paper's Scenario-3 rate-limiter value. Plus the α–β chunk model's
optimal gradient-bucket size for a v5e pod (the TPU adaptation of the
same trade-off).
"""
from __future__ import annotations

import math
import time

from repro.core import serialization as ser


def run() -> list[tuple[str, float, str]]:
    rows = []
    C = 1000.0  # Mbps, the paper's GbE
    t0 = time.perf_counter_ns()
    for N in (1, 10, 100, 1000, 10000, 100000):
        closed = ser.compounding_equilibrium(C, N)
        sim = ser.max_sustainable_ingest(C, N)
        rows.append((f"serialization.eq1_N{N}", (time.perf_counter_ns() - t0) / 1e3,
                     f"sim={sim:.3f}Mbps closed={closed:.3f}Mbps"))
    rows.append(("serialization.c_over_e", 0.0,
                 f"C/e={C/math.e:.2f}Mbps paper=367.92Mbps penalty={ser.throughput_penalty(C):.2f}Mbps"))
    # item-level refinement (beyond paper): penalty depends on k
    for k in (2, 8, 23):
        rows.append((f"serialization.item_level_k{k}", 0.0,
                     f"sustainable={ser.item_level_sustainable_ingest(C, k):.1f}Mbps(pkts)"))
    # TPU adaptation: bucket sizing for a 1B-param bf16 gradient on 16 hops
    link = ser.LinkModel()
    b = ser.optimal_bucket_bytes(2e9, 16, link)
    c = ser.optimal_chunks(2e9, 16, link)
    rows.append(("serialization.bucket_model", 0.0,
                 f"opt_bucket={b/2**20:.1f}MiB opt_chunks={c} "
                 f"t_1chunk={ser.ring_all_reduce_time(2e9,16,link,1)*1e3:.2f}ms "
                 f"t_opt={ser.ring_all_reduce_time(2e9,16,link,c)*1e3:.2f}ms"))
    return rows
