"""CI trace smoke: one compile + tune + simulate run with telemetry on,
exported as a Chrome trace and validated structurally.

Runs a skewed word-count shuffle through ``Session`` with a ``Telemetry``
attached and ``CostModel.sim_telemetry`` enabled, then asserts the
exported trace is Perfetto-loadable: valid JSON, monotonic timestamps
per track, matched span nesting (``repro.telemetry.validate_chrome_trace``)
— and that the spans the acceptance criteria name are actually present
(every pass, every autotune round, the simulate call). The streaming
surface rides along: a detector suite watches the run's windows, its
anomaly events export as Perfetto instant markers (``ph:"i"``) next to
a ``fabric.queue_depth`` counter track (``ph:"C"``), both of which must
validate and be present. Writes ``trace.json`` + ``metrics.json`` (CI
uploads both as artifacts) and prints the metrics dashboard. Exit 1 on
any failure.

    PYTHONPATH=src:. python benchmarks/trace_smoke.py [outdir]
"""
from __future__ import annotations

import json
import os
import sys


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    outdir = argv[0] if argv else "."

    from repro import p4mr
    from repro.compiler.cost import CostModel
    from repro.core import topology, wordcount
    from repro.telemetry import report as tel_report, validate_chrome_trace

    cm = CostModel(sim_telemetry=True, sim_telemetry_interval=4.0,
                   sim_telemetry_window=16.0)
    sess = p4mr.Session(
        topology.fat_tree_topology(4),
        cost_model=cm,
        telemetry=True,
        options=p4mr.CompileOptions(preset="autotuned", autotune_rounds=2),
    )
    prog = wordcount.wordcount_shuffle_program(
        4, 64, num_buckets=4,
        weights=(4.0, 1.0, 1.0, 1.0),
        hosts=[f"h{i}" for i in range(4)], sink_host="h15",
    )
    plan = sess.compile(prog, name="smoke")
    rep = sess.simulate()

    # streaming surface: a second tenant arriving mid-run gives the
    # detectors an onset to catch (a queue that only drains never trips
    # a growth detector); its events export onto the same tracer as
    # Perfetto instant markers + a counter track
    from repro.telemetry import WindowRecorder, default_detectors, export_to_tracer

    sess.compile(
        wordcount.wordcount_shuffle_program(
            4, 64, num_buckets=4,
            weights=(4.0, 1.0, 1.0, 1.0),
            hosts=[f"h{i}" for i in range(4, 8)], sink_host="h12",
        ),
        name="late",
    )
    suite = default_detectors(queue_threshold=4.0)
    rec = WindowRecorder()
    sess.simulate(arrivals={"late": 40.0}, observers=[suite, rec])
    export_to_tracer(sess.telemetry.tracer, suite.events, rec.windows)
    sess.telemetry.record_anomalies(suite.events)

    failures: list[str] = []
    if not rec.windows:
        failures.append("window stream produced no windows")
    if not suite.events:
        failures.append("detector suite found no anomalies on the skewed cell")

    # fabric telemetry rode along on the report
    tl = rep.combined.timeline
    if tl is None:
        failures.append("SimReport.timeline is None with sim_telemetry=True")
    elif not tl.hop_records:
        failures.append("timeline carries no hop records")

    # the trace round-trips through JSON and validates structurally
    trace_path = os.path.join(outdir, "trace.json")
    metrics_path = os.path.join(outdir, "metrics.json")
    sess.telemetry.write_trace(trace_path)
    sess.telemetry.write_metrics(metrics_path)
    with open(trace_path) as f:
        trace = json.load(f)
    failures += validate_chrome_trace(trace)

    names = [e["name"] for e in trace["traceEvents"]]
    for want, why in (
        ("pass:", "compiler pass spans"),
        ("tune:round-", "autotune round spans"),
        ("eval:", "autotune candidate spans"),
        ("session.compile", "session compile span"),
        ("session.simulate", "session simulate span"),
        ("plan.simulate_timing", "simulation spans"),
    ):
        if not any(n.startswith(want) for n in names):
            failures.append(f"no {why} ({want}*) in the trace")
    ran = {r.name for r in plan.pass_records}
    spanned = {n[len("pass:"):] for n in names if n.startswith("pass:")}
    if not ran <= spanned:
        failures.append(f"passes without spans: {sorted(ran - spanned)}")

    # the streaming export must land as instant + counter marks
    phs = {e["ph"] for e in trace["traceEvents"]}
    if "i" not in phs:
        failures.append("no instant (ph:'i') anomaly markers in the trace")
    if "C" not in phs:
        failures.append("no counter (ph:'C') queue-depth samples in the trace")

    with open(metrics_path) as f:
        metrics = json.load(f)
    for counter in ("session.compiles", "session.simulations", "tune.rounds"):
        if not metrics.get("counters", {}).get(counter):
            failures.append(f"metric counter {counter!r} missing or zero")

    print(tel_report.render(metrics))
    print(f"\ntrace: {len(names)} events -> {trace_path}")
    if failures:
        print(f"FAIL: {len(failures)} problem(s):")
        for msg in failures:
            print(f"  {msg}")
        return 1
    print("OK: trace validates (monotonic ts, matched nesting, all spans present)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
