"""Simulator-engine benchmark: event-ordered reference vs vectorized
tick-synchronous VOQ core on k∈{4,8} fat-tree shuffles (≥1e5 packets).

Each cell compiles one word-count shuffle to static-ECMP routes, builds
the packet-train spec once, then times both engines on the *same* spec —
so the measurement is pure engine time, excluding compile and train
construction. Two train modes per cell:

* ``cap`` — the production default (``CostModel.sim_train_cap`` batches
  long trains); what autotune/reroute evaluations actually pay;
* ``per_packet`` — ``sim_train_cap`` lifted so every packet is its own
  event; the regime where the event engine's per-packet Python loop is
  quadratic-ish in traffic and the dense engine's advantage peaks.

Writes a BENCH_simulator.json artifact. CI's bench-smoke gates
``speedup_vs_event`` as a higher-is-better metric (a same-machine
wall-clock *ratio*, so it is stable across runner speeds, unlike the
absolute packets/sec fields, which are reported but not gated) and the
cross-engine makespan agreement via ``makespan_pct_diff``.

    PYTHONPATH=src:. python benchmarks/run.py simulator
"""
from __future__ import annotations

import dataclasses
import os
import time

from repro import compiler
from repro.compiler.simulator import build_flow_spec, simulate_timing
from repro.core import topology, wordcount

from benchmarks._provenance import write_bench

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                        "BENCH_simulator.json")

# (name, k, mappers, vocab, buckets, skew) — sized so even the k=4 cell
# streams >=1e5 packets. The uniform k=8 cell is the acceptance headline
# (the vectorized core's step count scales with makespan, so uniform
# traffic is its best case); the skewed cells pin the makespan agreement
# where contention actually bites.
CELLS = (
    ("fat_tree_k4", 4, 8, 4096, 8, 2.0),
    ("fat_tree_k8", 8, 16, 8192, 16, 0.0),
    ("fat_tree_k8", 8, 16, 8192, 16, 2.0),
)
REPEATS = 3
PER_PACKET_CAP = 10 ** 9  # lifts train batching entirely


def _weights(num_buckets: int, skew: float) -> tuple[float, ...] | None:
    if skew == 0.0:
        return None
    return tuple(1.0 / (b + 1) ** skew for b in range(num_buckets))


def _best_s(fn) -> float:
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _mode(plan, cost_model, mode: str) -> dict:
    spec = build_flow_spec(plan.program, plan.routes, cost_model)

    def run(eng):
        return simulate_timing(
            plan.program, plan.routes, cost_model, engine=eng, spec=spec)
    rep_e, rep_v = run("event"), run("vectorized")
    s_e, s_v = _best_s(lambda: run("event")), _best_s(lambda: run("vectorized"))
    pct = 100.0 * abs(rep_v.makespan_ticks - rep_e.makespan_ticks) / rep_e.makespan_ticks
    return {
        "mode": mode,
        "total_packets": spec.total_packets,
        "train_events": sum(len(f.train) for f in spec.flows),
        "event_ms": round(s_e * 1e3, 2),
        "vectorized_ms": round(s_v * 1e3, 2),
        "packets_per_sec_event": round(spec.total_packets / s_e),
        "packets_per_sec_vectorized": round(spec.total_packets / s_v),
        "speedup_vs_event": round(s_e / s_v, 2),
        "makespan_ticks_event": rep_e.makespan_ticks,
        "makespan_ticks_vectorized": rep_v.makespan_ticks,
        "makespan_pct_diff": round(pct, 3),
    }


def _case(name, k, mappers, vocab, buckets, skew) -> list[dict]:
    topo = topology.fat_tree_topology(k)
    prog = wordcount.wordcount_shuffle_program(
        mappers, vocab, num_buckets=buckets, weights=_weights(buckets, skew),
        hosts=[f"h{i}" for i in range(mappers)], sink_host=f"h{len(topo.hosts) - 1}",
    )
    plan = compiler.compile(prog, topo, passes=compiler.STATIC_ECMP_PASSES)
    records = []
    for mode, cm in (
        ("cap", plan.cost_model),
        ("per_packet", dataclasses.replace(plan.cost_model, sim_train_cap=PER_PACKET_CAP)),
    ):
        rec = {"name": f"{name}.b{buckets}.skew{skew}.{mode}", "topology": name}
        rec.update(_mode(plan, cm, mode))
        records.append(rec)
    return records


def run() -> list[tuple[str, float, str]]:
    records = []
    for cell in CELLS:
        records.extend(_case(*cell))

    write_bench(OUT_PATH, records)

    rows = []
    for r in records:
        rows.append((
            f"simulator.{r['name']}", r["vectorized_ms"] * 1e3,
            f"event={r['event_ms']}ms vectorized={r['vectorized_ms']}ms "
            f"speedup={r['speedup_vs_event']}x "
            f"pkts/s={r['packets_per_sec_vectorized']:.3g} "
            f"packets={r['total_packets']} "
            f"makespan={r['makespan_ticks_event']}/{r['makespan_ticks_vectorized']}t "
            f"(d={r['makespan_pct_diff']}%)",
        ))
    rows.append(("simulator.artifact", 0.0, f"wrote {os.path.basename(OUT_PATH)}"))
    return rows
