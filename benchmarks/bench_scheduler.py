"""Scheduler benchmark: what the online multi-tenant layer recovers.

Two cells, both on the k=4 fat-tree, writing BENCH_scheduler.json
(gated by CI's bench-smoke regression check):

* ``sched.fat_tree_k4.two_wordcounts`` — the exact contention pair from
  BENCH_compile's multi-job cell (combined 119t vs 87t solo): both
  tenants submitted at tick 0, scheduled vs the unscheduled merge. The
  acceptance bar for the subsystem lives here: ``makespan_ticks_scheduled``
  must be strictly below the unscheduled merge and never above it.
* ``sched.fat_tree_k4.staggered_arrivals`` — three tenants submitted at
  ticks 0/30/60 with weights and one deadline, under the "deadline"
  objective: the scheduler's arrival model + SLO steering on a rolling
  fabric.

    PYTHONPATH=src:. python benchmarks/run.py scheduler
    PYTHONPATH=src:. python benchmarks/bench_scheduler.py
"""
from __future__ import annotations

import os
import time

from repro import p4mr
from repro.core import topology

from benchmarks._provenance import write_bench

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                        "BENCH_scheduler.json")


def _wordcount_tenant(name: str, hosts: list[str], sink: str, vocab: int) -> p4mr.Job:
    # identical shape to bench_compile's two-tenant cell
    job = p4mr.job(name)
    keyed = [
        job.store(f"s{i}", host=h, items=vocab).key_by(4)
        for i, h in enumerate(hosts)
    ]
    keyed[0].reduce("SUM", *keyed[1:], label="R").collect(sink, label="OUT")
    return job


def _contention_pair_case() -> dict:
    """BENCH_compile's two-wordcount contention cell, scheduled."""
    ft = topology.fat_tree_topology(4)
    sess = p4mr.Session(ft)
    sched = p4mr.Scheduler(sess, reroute_rounds=3)
    sched.submit(_wordcount_tenant("tenant_a", [f"h{i}" for i in range(4)], "h15", 64),
                 name="tenant_a")
    sched.submit(_wordcount_tenant("tenant_b", [f"h{i}" for i in range(4, 8)], "h12", 64),
                 name="tenant_b")
    t0 = time.perf_counter()
    rep = sched.run()
    schedule_us = (time.perf_counter() - t0) * 1e6
    assert rep.makespan_ticks <= rep.unscheduled_makespan_ticks, rep.summary()
    return {
        "name": "sched.fat_tree_k4.two_wordcounts",
        "schedule_us": round(schedule_us, 2),
        "makespan_ticks_scheduled": rep.makespan_ticks,
        "makespan_ticks_unscheduled": rep.unscheduled_makespan_ticks,
        "recovered_ticks": rep.recovered_ticks,
        "contention_ticks": rep.contention_ticks,
        "makespan_ticks_solo_a": rep.solo_makespan_ticks["tenant_a"],
        "makespan_ticks_solo_b": rep.solo_makespan_ticks["tenant_b"],
        "weighted_flow_ticks": rep.weighted_flow_ticks,
        "admitted": len(rep.admitted),
        "hot_swaps_accepted": sum(1 for s in rep.hot_swaps if s.accepted),
    }


def _staggered_case() -> dict:
    """Three tenants arriving at ticks 0/30/60 under the deadline
    objective — the online story: admission order and tie-breaks follow
    the SLO, and late arrivals ride a fabric that is already loaded."""
    ft = topology.fat_tree_topology(4)
    sess = p4mr.Session(ft)
    sched = p4mr.Scheduler(sess, objective="deadline", reroute_rounds=2)
    sched.submit(_wordcount_tenant("etl", [f"h{i}" for i in range(4)], "h15", 64),
                 name="etl", at=0, weight=1.0)
    sched.submit(_wordcount_tenant("urgent", [f"h{i}" for i in range(4, 8)], "h12", 64),
                 name="urgent", at=30, deadline=150, weight=2.0)
    sched.submit(_wordcount_tenant("batch", [f"h{i}" for i in range(8, 12)], "h0", 64),
                 name="batch", at=60, weight=0.5)
    t0 = time.perf_counter()
    rep = sched.run()
    schedule_us = (time.perf_counter() - t0) * 1e6
    assert rep.makespan_ticks <= rep.unscheduled_makespan_ticks, rep.summary()
    return {
        "name": "sched.fat_tree_k4.staggered_arrivals",
        "schedule_us": round(schedule_us, 2),
        "makespan_ticks_scheduled": rep.makespan_ticks,
        "makespan_ticks_unscheduled": rep.unscheduled_makespan_ticks,
        "recovered_ticks": rep.recovered_ticks,
        "contention_ticks": rep.contention_ticks,
        "weighted_flow_ticks": rep.weighted_flow_ticks,
        "deadline_miss_ticks": sum(rep.deadline_miss_ticks.values()),
        "admitted": len(rep.admitted),
        "hot_swaps_accepted": sum(1 for s in rep.hot_swaps if s.accepted),
    }


def run() -> list[tuple[str, float, str]]:
    records = [_contention_pair_case(), _staggered_case()]
    write_bench(OUT_PATH, records)
    rows = []
    for r in records:
        rows.append((
            f"scheduler.{r['name']}", r["schedule_us"],
            f"scheduled={r['makespan_ticks_scheduled']}t "
            f"unscheduled={r['makespan_ticks_unscheduled']}t "
            f"recovered={r['recovered_ticks']}t "
            f"contention=+{r['contention_ticks']}t "
            f"wflow={r['weighted_flow_ticks']}",
        ))
    rows.append(("scheduler.artifact", 0.0, f"wrote {os.path.basename(OUT_PATH)}"))
    return rows


if __name__ == "__main__":
    for row, us, derived in run():
        print(f"{row},{us:.2f},{derived}")
