"""In-transit vs endpoint aggregation (the paper's core claim, TPU form).

(a) Analytic wire bytes per device for aggregating a 1-GB gradient over
    16 DP hosts under each scenario (S1 endpoint vs S2/S3 in-transit) —
    the collective roofline term each scenario pays.
(b) Measured wall time of each scenario's training step on 8 virtual CPU
    devices (subprocess) — functional evidence the schedules run.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from repro.core.scenarios import Scenario, wire_bytes_per_device

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")

_MEASURE = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import time, json
import jax, jax.numpy as jnp, numpy as np
from repro.launch import steps
from repro.launch.mesh import make_mesh
from repro.configs import get_smoke_config
from repro.models.common import init_params

mesh = make_mesh((4, 2), ("data", "model"))
cfg = get_smoke_config("qwen1_5_0_5b")
out = {}
for sc in ["native", "s1_host", "s2_in_net", "s3_in_net_map"]:
    step, env, b = steps.make_train_step(cfg, mesh, scenario=sc,
        microbatches=1, global_batch=8, seq=32)
    params = init_params(b["param_leafspecs"], 0, jnp.float32, env)
    params = jax.device_put(params, jax.tree_util.tree_map(
        lambda p: jax.sharding.NamedSharding(mesh, p), b["param_partition"]))
    state = b["init_state"](params)
    rng = np.random.RandomState(0)
    batch = jax.tree_util.tree_map(
        lambda s: rng.randint(0, cfg.vocab, s.shape).astype(np.int32), b["batch_sds"])
    params, state, m = step(params, state, batch)  # compile+warm
    t0 = time.perf_counter()
    for _ in range(5):
        params, state, m = step(params, state, batch)
    jax.block_until_ready(m["loss"])
    out[sc] = (time.perf_counter() - t0) / 5
print(json.dumps(out))
"""


def run() -> list[tuple[str, float, str]]:
    rows = []
    nbytes = 1e9
    for sc in Scenario:
        w = wire_bytes_per_device(nbytes, 16, sc)
        rows.append((f"collectives.wire.{sc.value}", 0.0,
                     f"wire_bytes/dev={w/1e9:.3f}GB t_ici={w/50e9*1e3:.1f}ms"))
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _MEASURE], env=env,
                          capture_output=True, text=True, timeout=560)
    if proc.returncode == 0:
        times = json.loads(proc.stdout.strip().splitlines()[-1])
        for sc, t in times.items():
            rows.append((f"collectives.step.{sc}", t * 1e6,
                         f"8dev cpu step={t*1e3:.1f}ms"))
    else:
        rows.append(("collectives.step.error", 0.0, proc.stderr[-200:]))
    return rows
