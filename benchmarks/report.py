"""Render EXPERIMENTS.md tables from the dry-run/hillclimb JSONs so the
document can never disagree with the measured artifacts.

    PYTHONPATH=src python benchmarks/report.py dryrun results_dryrun_single.json
    PYTHONPATH=src python benchmarks/report.py roofline results_dryrun_single.json
    PYTHONPATH=src python benchmarks/report.py perf results_hillclimb.json

``--history`` is the perf-trajectory view over the committed BENCH_*.json
artifacts: for each one it reads the provenance record leading the file
(when/where/which sha produced the numbers) in both the working tree and
the committed baseline (``git show HEAD:...``), then prints per-cell
deltas of every gated metric — the same metric set
``check_regression.py`` enforces, so "what moved since the last commit"
and "what CI will gate" are one list.

    python benchmarks/report.py --history            # repo root
    python benchmarks/report.py --history path/to/repo
"""
from __future__ import annotations

import json
import os
import subprocess
import sys


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(path):
    recs = json.load(open(path))
    print("| arch | shape | mesh | tp×rep | mb | compile | peak HBM/dev | fits 16G | status |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if "skipped" in r:
            print(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | "
                  f"SKIP: {r['skipped'][:48]} |")
            continue
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['tp']}×{r['rep']} "
              f"| {r['microbatches']} | {r.get('compile_s','—')}s "
              f"| {fmt_bytes(r['peak_hbm_bytes_per_dev'])} GiB "
              f"| {'✓' if r.get('fits_16g') else '✗'} | compiled |")


def roofline_table(path):
    recs = json.load(open(path))
    print("| arch/shape | FLOPs/dev | HBM B/dev | wire B/dev | t_comp | t_mem | t_coll "
          "| bottleneck | 6ND/HLO | roofline | lever |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    levers = {
        ("memory", "train"): "fuse attention into VMEM (flash kernel) — kills the fp32 score traffic",
        ("memory", "prefill"): "flash kernel + longer arithmetic chains per byte",
        ("memory", "decode"): "batch more requests per chip (HBM is streamed weights)",
        ("collective", "train"): "bucket+overlap grad rings behind backward compute",
        ("collective", "prefill"): "overlap TP psums with the next layer's matmul",
        ("collective", "decode"): "compute-at-data: ship activations, not weights (§Perf H2)",
        ("compute", "train"): "triangle-causal schedule (drop the masked upper half)",
        ("compute", "prefill"): "triangle-causal schedule",
        ("compute", "decode"): "already compute-lean; batch for MXU occupancy",
    }
    for r in recs:
        if "t_compute_s" not in r:
            continue
        kind = ("train" if "train" in r["shape"] else
                "prefill" if "prefill" in r["shape"] else "decode")
        print(f"| {r['arch']}/{r['shape']} | {r['flops_per_dev']:.2e} "
              f"| {r['hbm_bytes_per_dev']:.2e} | {r['wire_bytes_per_dev']:.2e} "
              f"| {r['t_compute_s']*1e3:.1f}ms | {r['t_memory_s']*1e3:.1f}ms "
              f"| {r['t_collective_s']*1e3:.1f}ms | **{r['bottleneck']}** "
              f"| {r['useful_flops_ratio']:.2f} | {r.get('roofline_fraction',0):.3f} "
              f"| {levers[(r['bottleneck'], kind)]} |")


def perf_table(path):
    recs = json.load(open(path))
    print("| iteration | t_comp | t_mem | t_coll | bottleneck | wire GB/dev | roofline |")
    print("|---|---|---|---|---|---|---|")
    for r in recs:
        if "t_compute_s" not in r:
            print(f"| {r.get('variant','?')} | ERROR {r.get('error','')[:60]} | | | | | |")
            continue
        print(f"| {r['variant']} | {r['t_compute_s']*1e3:.1f}ms "
              f"| {r['t_memory_s']*1e3:.1f}ms | {r['t_collective_s']*1e3:.1f}ms "
              f"| {r['bottleneck']} | {r['wire_bytes_per_dev']/1e9:.2f} "
              f"| {r.get('roofline_fraction',0):.4f} |")


def _prov_line(prov):
    if not prov:
        return "(no provenance record)"
    ts = prov.get("timestamp_utc", "?")
    sha = prov.get("git_sha", "?")
    return f"{ts} @{sha} on {prov.get('host', '?')}"


def history(root: str | None = None) -> int:
    """Per-cell gated-metric deltas: working tree vs committed baseline,
    for every BENCH_*.json under ``root`` (default: the repo root above
    benchmarks/). Exit code 0 always — this is a trend view, not a gate
    (``check_regression.py`` is the gate)."""
    try:
        from benchmarks._provenance import strip_provenance
        from benchmarks.check_regression import (
            GATED_METRICS,
            HIGHER_IS_BETTER,
            cell_label,
            record_key,
        )
    except ImportError:  # run as a script: benchmarks/ itself is sys.path[0]
        from _provenance import strip_provenance
        from check_regression import (
            GATED_METRICS,
            HIGHER_IS_BETTER,
            cell_label,
            record_key,
        )

    if root is None:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    names = sorted(
        f for f in os.listdir(root)
        if f.startswith("BENCH_") and f.endswith(".json")
    )
    if not names:
        print(f"no BENCH_*.json artifacts under {root}")
        return 0
    for fname in names:
        with open(os.path.join(root, fname)) as f:
            cur_prov, cur = strip_provenance(json.load(f))
        try:
            blob = subprocess.run(
                ["git", "show", f"HEAD:{fname}"], cwd=root,
                capture_output=True, text=True, timeout=10, check=True,
            ).stdout
            base_prov, base = strip_provenance(json.loads(blob))
        except Exception:
            base_prov, base = None, None
        print(f"== {fname} ==")
        print(f"  current : {_prov_line(cur_prov)}")
        if base is None:
            print("  baseline: (not committed yet — every cell is new)")
        else:
            print(f"  baseline: {_prov_line(base_prov)}")
        base_by_key = {record_key(r): r for r in (base or [])}
        for rec in cur:
            key = record_key(rec)
            b = base_by_key.get(key)
            lines = []
            for metric in (*GATED_METRICS, *HIGHER_IS_BETTER):
                if metric not in rec:
                    continue
                c = float(rec[metric])
                if b is None or metric not in b:
                    lines.append(f"    {metric:<32} {'(new)':>12} -> {c:g}")
                    continue
                bv = float(b[metric])
                delta = (c - bv) / bv * 100.0 if bv else 0.0
                flag = "" if abs(delta) < 1e-9 else f"  ({delta:+.1f}%)"
                lines.append(f"    {metric:<32} {bv:>12g} -> {c:g}{flag}")
            if lines:
                print(f"  cell [{cell_label(key)}]"
                      + ("  (new — no baseline)" if b is None else ""))
                print("\n".join(lines))
    return 0


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] in ("--history", "history"):
        sys.exit(history(sys.argv[2] if len(sys.argv) > 2 else None))
    kind, path = sys.argv[1], sys.argv[2]
    {"dryrun": dryrun_table, "roofline": roofline_table, "perf": perf_table}[kind](path)
