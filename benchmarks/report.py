"""Render EXPERIMENTS.md tables from the dry-run/hillclimb JSONs so the
document can never disagree with the measured artifacts.

    PYTHONPATH=src python benchmarks/report.py dryrun results_dryrun_single.json
    PYTHONPATH=src python benchmarks/report.py roofline results_dryrun_single.json
    PYTHONPATH=src python benchmarks/report.py perf results_hillclimb.json
"""
from __future__ import annotations

import json
import sys


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(path):
    recs = json.load(open(path))
    print("| arch | shape | mesh | tp×rep | mb | compile | peak HBM/dev | fits 16G | status |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if "skipped" in r:
            print(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | "
                  f"SKIP: {r['skipped'][:48]} |")
            continue
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['tp']}×{r['rep']} "
              f"| {r['microbatches']} | {r.get('compile_s','—')}s "
              f"| {fmt_bytes(r['peak_hbm_bytes_per_dev'])} GiB "
              f"| {'✓' if r.get('fits_16g') else '✗'} | compiled |")


def roofline_table(path):
    recs = json.load(open(path))
    print("| arch/shape | FLOPs/dev | HBM B/dev | wire B/dev | t_comp | t_mem | t_coll "
          "| bottleneck | 6ND/HLO | roofline | lever |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    levers = {
        ("memory", "train"): "fuse attention into VMEM (flash kernel) — kills the fp32 score traffic",
        ("memory", "prefill"): "flash kernel + longer arithmetic chains per byte",
        ("memory", "decode"): "batch more requests per chip (HBM is streamed weights)",
        ("collective", "train"): "bucket+overlap grad rings behind backward compute",
        ("collective", "prefill"): "overlap TP psums with the next layer's matmul",
        ("collective", "decode"): "compute-at-data: ship activations, not weights (§Perf H2)",
        ("compute", "train"): "triangle-causal schedule (drop the masked upper half)",
        ("compute", "prefill"): "triangle-causal schedule",
        ("compute", "decode"): "already compute-lean; batch for MXU occupancy",
    }
    for r in recs:
        if "t_compute_s" not in r:
            continue
        kind = ("train" if "train" in r["shape"] else
                "prefill" if "prefill" in r["shape"] else "decode")
        print(f"| {r['arch']}/{r['shape']} | {r['flops_per_dev']:.2e} "
              f"| {r['hbm_bytes_per_dev']:.2e} | {r['wire_bytes_per_dev']:.2e} "
              f"| {r['t_compute_s']*1e3:.1f}ms | {r['t_memory_s']*1e3:.1f}ms "
              f"| {r['t_collective_s']*1e3:.1f}ms | **{r['bottleneck']}** "
              f"| {r['useful_flops_ratio']:.2f} | {r.get('roofline_fraction',0):.3f} "
              f"| {levers[(r['bottleneck'], kind)]} |")


def perf_table(path):
    recs = json.load(open(path))
    print("| iteration | t_comp | t_mem | t_coll | bottleneck | wire GB/dev | roofline |")
    print("|---|---|---|---|---|---|---|")
    for r in recs:
        if "t_compute_s" not in r:
            print(f"| {r.get('variant','?')} | ERROR {r.get('error','')[:60]} | | | | | |")
            continue
        print(f"| {r['variant']} | {r['t_compute_s']*1e3:.1f}ms "
              f"| {r['t_memory_s']*1e3:.1f}ms | {r['t_collective_s']*1e3:.1f}ms "
              f"| {r['bottleneck']} | {r['wire_bytes_per_dev']/1e9:.2f} "
              f"| {r.get('roofline_fraction',0):.4f} |")


if __name__ == "__main__":
    kind, path = sys.argv[1], sys.argv[2]
    {"dryrun": dryrun_table, "roofline": roofline_table, "perf": perf_table}[kind](path)
