import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """§Perf hillclimb driver — hypothesis → change → re-lower → re-analyse.

Three cells (chosen from the baseline roofline table):
  H1 qwen1.5-0.5b/train_4k      — most representative of the paper: the
     S1→S2→S3 scenario ladder IS the paper's experiment, run as the
     gradient-aggregation engine; then beyond-paper (native psum,
     triangle-causal attention, flash-attention memory accounting).
  H2 grok-1-314b/decode_32k     — most collective-bound cell: serving
     weight-gather vs compute-at-data (activations travel, weights stay).
  H3 granite-moe-1b-a400m/train_4k — worst roofline fraction: triangle
     attention + flash memory accounting + microbatch tuning.

Each iteration records the full three-term roofline; the flash-attention
variant additionally swaps the measured quadratic (score-materialization)
HBM bytes for the Pallas kernel's true working-set traffic, extracted by a
seq-halving probe pair (bytes(s) = a·s + b·s² → b isolated exactly).

Writes results_hillclimb.json; EXPERIMENTS.md §Perf narrates it.
"""
import dataclasses
import json
import time


from repro.analysis import roofline as rl
from repro.configs import get_config
from repro.launch import dryrun, shapes as shp


def flash_quad_extraction(arch: str, shape_name: str, *, scenario, impl, mb):
    """Return (quad_bytes, kernel_quad_bytes) for the cell's full depth."""
    cfg = get_config(arch)
    shape = shp.SHAPES[shape_name]
    from repro.launch.mesh import make_production_mesh
    from repro.models.model import block_pattern

    mesh = make_production_mesh()
    unit, tail, n_units = block_pattern(cfg)

    def probe_bytes(seq):
        sh = dataclasses.replace(shape, seq_len=seq)
        c = dryrun._reduce_depth(cfg, 1)
        lw, env = dryrun._build(c, sh, mesh, scenario=scenario, impl="direct",
                                microbatches=1, unroll=True)
        return rl.cost_vector(lw, lw.compile())[1], env  # hbm bytes

    s = shape.seq_len
    b_s, env = probe_bytes(s)
    b_h, _ = probe_bytes(s // 2)
    quad_1layer = 2.0 * (b_s - 2.0 * b_h)  # b·s² of ONE unit, full batch
    per_unit_attn, tail_attn = rl.attn_layers_per_unit_and_tail(cfg)
    # microbatching splits batch, not seq: total quadratic bytes per step
    # are mb-invariant (the probe already covers the full batch at mb=1)
    scale = 1
    quad_total = max(0.0, quad_1layer) * n_units * scale
    # Pallas flash kernel true quadratic traffic: each q-block re-reads K,V
    # (sk × h_loc × (hd_k + hd_v) bytes), nq = s/block_q passes per layer.
    seq_eff = s // (2 if cfg.enc_layers else 1)
    h_loc = cfg.n_heads // max(1, env.tp)
    hd_k = (cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim) if cfg.mla else cfg.hd
    hd_v = cfg.mla.v_head_dim if cfg.mla else cfg.hd
    block_q = 128
    b_loc = env.local_batch(shape.global_batch) // scale
    n_attn = per_unit_attn * n_units + tail_attn
    passes = seq_eff // block_q
    kernel_quad = (passes * seq_eff * h_loc * (hd_k + hd_v) * 2  # K,V re-reads
                   ) * b_loc * n_attn * scale
    remat_factor = 4.0 if shape.kind == "train" else 1.0
    return quad_total, kernel_quad * remat_factor


def run_variant(arch, shape_name, *, scenario="native", impl="masked",
                microbatches=None, flash=False, label="", overrides=None):
    t0 = time.time()
    rec = dryrun.lower_cell(arch, shape_name, scenario=scenario, impl=impl,
                            microbatches=microbatches, cfg_overrides=overrides)
    rec["variant"] = label
    if flash and "hbm_bytes_per_dev" in rec:
        mb = rec["microbatches"]
        quad, kq = flash_quad_extraction(arch, shape_name, scenario=scenario,
                                         impl=impl, mb=mb)
        new_bytes = max(0.0, rec["hbm_bytes_per_dev"] - quad + kq)
        rec["flash_quad_bytes_removed"] = quad
        rec["flash_kernel_bytes_added"] = kq
        rec["hbm_bytes_per_dev"] = new_bytes
        rec["t_memory_s"] = new_bytes / rl.HBM_BW
        terms = {"compute": rec["t_compute_s"], "memory": rec["t_memory_s"],
                 "collective": rec["t_collective_s"]}
        rec["bottleneck"] = max(terms, key=terms.get)
        tmax = max(terms.values())
        rec["roofline_fraction"] = (rec["flops_per_dev"] / rl.PEAK_FLOPS) / tmax \
            * rec["useful_flops_ratio"] if tmax else 0.0
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def main():
    out = []

    def log(r):
        out.append(r)
        keys = ("variant", "t_compute_s", "t_memory_s", "t_collective_s",
                "bottleneck", "useful_flops_ratio", "roofline_fraction")
        print(json.dumps({k: r.get(k) for k in keys}))
        with open("results_hillclimb.json", "w") as f:
            json.dump(out, f, indent=1)

    # ---------------- H1: qwen1.5-0.5b train_4k — the paper ladder --------
    for sc, lbl in [("s1_host", "H1.0 S1 endpoint (paper baseline-of-baselines)"),
                    ("s2_in_net", "H1.1 S2 in-transit ring (paper-faithful)"),
                    ("s3_in_net_map", "H1.2 S3 ring + bf16 wire (paper-faithful)"),
                    ("native", "H1.3 native psum (beyond paper)")]:
        log(run_variant("qwen1.5-0.5b", "train_4k", scenario=sc, label=lbl))
    log(run_variant("qwen1.5-0.5b", "train_4k", scenario="native",
                    impl="triangle", label="H1.4 + triangle-causal attention"))
    log(run_variant("qwen1.5-0.5b", "train_4k", scenario="native",
                    impl="triangle", flash=True,
                    label="H1.5 + pallas flash attention (memory accounting)"))
    # tp=16 over-shards a 0.5B model: TP activation psums dominate the
    # collective term. Right-size to tp=4 and spend the freed model-axis
    # factor as extra data parallelism (rep-groups batch split).
    log(run_variant("qwen1.5-0.5b", "train_4k", scenario="native",
                    impl="triangle", flash=True, overrides={"tp": 4},
                    label="H1.6 + right-size tp 16->4 (rep as DP)"))
    log(run_variant("qwen1.5-0.5b", "train_4k", scenario="s2_in_net",
                    impl="triangle", flash=True, overrides={"tp": 4},
                    label="H1.7 best layout, paper-faithful S2 ring"))

    # ---------------- H2: grok decode — compute at data -------------------
    log(run_variant("grok-1-314b", "decode_32k", label="H2.0 baseline (weight gather)"))
    log(run_variant("grok-1-314b", "decode_32k", impl="serve_opt",
                    label="H2.1 compute-at-data serving"))

    # ---------------- H3: granite-moe train — worst fraction --------------
    log(run_variant("granite-moe-1b-a400m", "train_4k", label="H3.0 baseline"))
    log(run_variant("granite-moe-1b-a400m", "train_4k", impl="triangle",
                    label="H3.1 + triangle-causal attention"))
    log(run_variant("granite-moe-1b-a400m", "train_4k", impl="triangle",
                    flash=True, label="H3.2 + flash attention memory"))
    log(run_variant("granite-moe-1b-a400m", "train_4k", impl="triangle",
                    flash=True, microbatches=1,
                    label="H3.3 + microbatches 2->1"))
    log(run_variant("granite-moe-1b-a400m", "train_4k", impl="triangle",
                    flash=True, microbatches=1, overrides={"tp": 8},
                    label="H3.4 + right-size tp 16->8 (4 experts/rank)"))

    print(f"\n{len(out)} variants -> results_hillclimb.json")


if __name__ == "__main__":
    main()
