import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """§Perf hillclimb driver — hypothesis → change → re-lower → re-analyse.

Three cells (chosen from the baseline roofline table):
  H1 qwen1.5-0.5b/train_4k      — most representative of the paper: the
     S1→S2→S3 scenario ladder IS the paper's experiment, run as the
     gradient-aggregation engine; then beyond-paper (native psum,
     triangle-causal attention, flash-attention memory accounting).
  H2 grok-1-314b/decode_32k     — most collective-bound cell: serving
     weight-gather vs compute-at-data (activations travel, weights stay).
  H3 granite-moe-1b-a400m/train_4k — worst roofline fraction: triangle
     attention + flash memory accounting + microbatch tuning.

The climb itself is ``repro.autotune.search.hill_climb`` — the same
greedy accept-if-better driver the plan autotuner uses — walking a fixed
per-cell ladder of variants (``stop_when_stuck=False``: every rung is
measured and logged even when it does not win) against the modelled
step-time bound max(t_compute, t_memory, t_collective); a rejected rung's
settings are not carried into later rungs.

Each iteration records the full three-term roofline; the flash-attention
variant additionally swaps the measured quadratic (score-materialization)
HBM bytes for the Pallas kernel's true working-set traffic, extracted by a
seq-halving probe pair (bytes(s) = a·s + b·s² → b isolated exactly).

Writes results_hillclimb.json; EXPERIMENTS.md §Perf narrates it.
"""
import dataclasses
import json
import time


from repro.analysis import roofline as rl
from repro.configs import get_config
from repro.launch import dryrun, shapes as shp


def flash_quad_extraction(arch: str, shape_name: str, *, scenario, impl, mb):
    """Return (quad_bytes, kernel_quad_bytes) for the cell's full depth."""
    cfg = get_config(arch)
    shape = shp.SHAPES[shape_name]
    from repro.launch.mesh import make_production_mesh
    from repro.models.model import block_pattern

    mesh = make_production_mesh()
    unit, tail, n_units = block_pattern(cfg)

    def probe_bytes(seq):
        sh = dataclasses.replace(shape, seq_len=seq)
        c = dryrun._reduce_depth(cfg, 1)
        lw, env = dryrun._build(c, sh, mesh, scenario=scenario, impl="direct",
                                microbatches=1, unroll=True)
        return rl.cost_vector(lw, lw.compile())[1], env  # hbm bytes

    s = shape.seq_len
    b_s, env = probe_bytes(s)
    b_h, _ = probe_bytes(s // 2)
    quad_1layer = 2.0 * (b_s - 2.0 * b_h)  # b·s² of ONE unit, full batch
    per_unit_attn, tail_attn = rl.attn_layers_per_unit_and_tail(cfg)
    # microbatching splits batch, not seq: total quadratic bytes per step
    # are mb-invariant (the probe already covers the full batch at mb=1)
    scale = 1
    quad_total = max(0.0, quad_1layer) * n_units * scale
    # Pallas flash kernel true quadratic traffic: each q-block re-reads K,V
    # (sk × h_loc × (hd_k + hd_v) bytes), nq = s/block_q passes per layer.
    seq_eff = s // (2 if cfg.enc_layers else 1)
    h_loc = cfg.n_heads // max(1, env.tp)
    hd_k = (cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim) if cfg.mla else cfg.hd
    hd_v = cfg.mla.v_head_dim if cfg.mla else cfg.hd
    block_q = 128
    b_loc = env.local_batch(shape.global_batch) // scale
    n_attn = per_unit_attn * n_units + tail_attn
    passes = seq_eff // block_q
    kernel_quad = (passes * seq_eff * h_loc * (hd_k + hd_v) * 2  # K,V re-reads
                   ) * b_loc * n_attn * scale
    remat_factor = 4.0 if shape.kind == "train" else 1.0
    return quad_total, kernel_quad * remat_factor


def run_variant(arch, shape_name, *, scenario="native", impl="masked",
                microbatches=None, flash=False, label="", overrides=None):
    t0 = time.time()
    rec = dryrun.lower_cell(arch, shape_name, scenario=scenario, impl=impl,
                            microbatches=microbatches, cfg_overrides=overrides)
    rec["variant"] = label
    if flash and "hbm_bytes_per_dev" in rec:
        mb = rec["microbatches"]
        quad, kq = flash_quad_extraction(arch, shape_name, scenario=scenario,
                                         impl=impl, mb=mb)
        new_bytes = max(0.0, rec["hbm_bytes_per_dev"] - quad + kq)
        rec["flash_quad_bytes_removed"] = quad
        rec["flash_kernel_bytes_added"] = kq
        rec["hbm_bytes_per_dev"] = new_bytes
        rec["t_memory_s"] = new_bytes / rl.HBM_BW
        terms = {"compute": rec["t_compute_s"], "memory": rec["t_memory_s"],
                 "collective": rec["t_collective_s"]}
        rec["bottleneck"] = max(terms, key=terms.get)
        tmax = max(terms.values())
        rec["roofline_fraction"] = (rec["flops_per_dev"] / rl.PEAK_FLOPS) / tmax \
            * rec["useful_flops_ratio"] if tmax else 0.0
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


# Per-cell variant ladders for the shared hill-climb driver. Each rung is
# one round of candidates: (label, settings delta vs the incumbent). The
# first H1 rung offers the whole S2/S3/native scenario alternative set at
# once (steepest descent picks the best aggregation strategy); later
# rungs stack one hypothesis each.
LADDERS = [
    ("qwen1.5-0.5b", "train_4k",
     ("H1.0 S1 endpoint (paper baseline-of-baselines)", {"scenario": "s1_host"}),
     [
         [("H1.1 S2 in-transit ring (paper-faithful)", {"scenario": "s2_in_net"}),
          ("H1.2 S3 ring + bf16 wire (paper-faithful)", {"scenario": "s3_in_net_map"}),
          ("H1.3 native psum (beyond paper)", {"scenario": "native"})],
         [("H1.4 + triangle-causal attention", {"impl": "triangle"})],
         [("H1.5 + pallas flash attention (memory accounting)", {"flash": True})],
         # tp=16 over-shards a 0.5B model: TP activation psums dominate the
         # collective term. Right-size to tp=4 and spend the freed
         # model-axis factor as extra data parallelism (rep-groups split).
         [("H1.6 + right-size tp 16->4 (rep as DP)", {"overrides": {"tp": 4}})],
         [("H1.7 best layout, paper-faithful S2 ring", {"scenario": "s2_in_net"})],
     ]),
    ("grok-1-314b", "decode_32k",
     ("H2.0 baseline (weight gather)", {}),
     [
         [("H2.1 compute-at-data serving", {"impl": "serve_opt"})],
     ]),
    ("granite-moe-1b-a400m", "train_4k",
     ("H3.0 baseline", {}),
     [
         [("H3.1 + triangle-causal attention", {"impl": "triangle"})],
         [("H3.2 + flash attention memory", {"flash": True})],
         [("H3.3 + microbatches 2->1", {"microbatches": 1})],
         [("H3.4 + right-size tp 16->8 (4 experts/rank)", {"overrides": {"tp": 8}})],
     ]),
]


def _merge(settings: dict, delta: dict) -> dict:
    merged = {**settings, **delta}
    if "overrides" in settings or "overrides" in delta:
        merged["overrides"] = {**(settings.get("overrides") or {}),
                               **(delta.get("overrides") or {})}
    return merged


def _step_bound(rec: dict) -> float:
    """Objective: the modelled per-step time bound (lower is better)."""
    terms = [rec.get("t_compute_s"), rec.get("t_memory_s"), rec.get("t_collective_s")]
    terms = [t for t in terms if t is not None]
    return max(terms) if terms else float("inf")


def climb_cell(arch, shape_name, base, ladder, log):
    """Walk one cell's ladder with the shared autotune hill-climb."""
    from repro.autotune import search

    def measure(label, settings):
        rec = run_variant(arch, shape_name, label=label, **settings)
        # greedy acceptance means a rung can be measured WITHOUT a rejected
        # earlier rung's delta — the label narrates the ladder, this field
        # records what actually ran
        rec["settings"] = settings
        return settings, rec

    base_label, base_delta = base
    state = measure(base_label, _merge({}, base_delta))
    log(state[1])

    def propose(st, rnd):
        return [
            search.Candidate(
                kind="variant",
                detail=label,
                build=lambda label=label, delta=delta, st=st: measure(
                    label, _merge(st[0], delta)
                ),
            )
            for label, delta in ladder[rnd - 1]
        ]

    best, _, _ = search.hill_climb(
        state,
        objective=lambda st: _step_bound(st[1]),
        propose=propose,
        rounds=len(ladder),
        on_eval=lambda _rec, st: log(st[1]),
        stop_when_stuck=False,  # measure every rung, accept only winners
    )
    return best


def main():
    out = []

    def log(r):
        out.append(r)
        keys = ("variant", "t_compute_s", "t_memory_s", "t_collective_s",
                "bottleneck", "useful_flops_ratio", "roofline_fraction")
        print(json.dumps({k: r.get(k) for k in keys}))
        with open("results_hillclimb.json", "w") as f:
            json.dump(out, f, indent=1)

    for arch, shape_name, base, ladder in LADDERS:
        climb_cell(arch, shape_name, base, ladder, log)

    print(f"\n{len(out)} variants -> results_hillclimb.json")


if __name__ == "__main__":
    main()
