"""Autotune benchmark: static vs feedback vs autotuned streamed makespans.

For each skewed shuffle cell the word-count program is compiled three
ways — static route-count ECMP (``STATIC_ECMP_PASSES``), the full
pipeline whose ``reroute-feedback`` pass already re-routes on measured
queueing (``DEFAULT_PASSES``), and that feedback plan hill-climbed by
``repro.autotune`` (reroute detours, reducer moves, rebucket, learned
reweight). The tuned plan must never lose to the feedback plan it starts
from, and on the skewed cells it should win by >=10% — the per-action
attribution in each record's ``tuning`` block says which mutation bought
the ticks. Simulator outputs are checked against the numpy reference on
every cell: tuning must never change values.

Writes a BENCH_autotune.json artifact; CI's bench-smoke job gates the
simulated metrics at >10% regression (``benchmarks/check_regression.py``)
and prints the accepted-action summary (``--summary``).

    PYTHONPATH=src:. python benchmarks/run.py autotune
    PYTHONPATH=src:. python benchmarks/bench_autotune.py --summary
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

# cell scaffolding (topologies, skew weights, seeded inputs, sizes) is
# bench_shuffle's: the static/feedback columns of the two BENCH jsons must
# stay comparable cell for cell
from benchmarks.bench_shuffle import N_MAPPERS, VOCAB, _topologies, _weights, case_inputs
from benchmarks._provenance import strip_provenance, write_bench

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                        "BENCH_autotune.json")

TUNE_ROUNDS = 6
# (topology key, num_buckets, skew): the skewed fat-tree/torus cells where
# feedback routing alone leaves queueing on the table, plus one uniform
# control cell where the tuner should find (almost) nothing
CASES = (
    ("fat_tree_k4", 8, 2.0),
    ("fat_tree_k4", 4, 1.0),
    ("torus_4x4", 8, 2.0),
    ("torus_4x4", 8, 0.0),
)


def _topology(name: str):
    for topo_name, topo, hosts, sink in _topologies():
        if topo_name == name:
            return topo, hosts, sink
    raise KeyError(f"unknown benchmark topology {name!r}")


def _case(topo_name: str, num_buckets: int, skew: float) -> dict:
    from repro import autotune, compiler
    from repro.core import wordcount

    topo, hosts, sink = _topology(topo_name)
    prog = wordcount.wordcount_shuffle_program(
        N_MAPPERS, VOCAB, num_buckets=num_buckets,
        weights=_weights(num_buckets, skew), hosts=hosts, sink_host=sink,
    )
    static = compiler.compile(prog, topo, passes=compiler.STATIC_ECMP_PASSES)
    feedback = compiler.compile(prog, topo)
    t0 = time.perf_counter()
    tuned = autotune.tune(feedback, rounds=TUNE_ROUNDS)
    tune_us = (time.perf_counter() - t0) * 1e6

    inputs = case_inputs(num_buckets, skew)
    sim = tuned.simulate(inputs)
    ref = np.sum([inputs[f"s{i}"] for i in range(N_MAPPERS)], axis=0)
    np.testing.assert_array_equal(sim.outputs["OUT"], ref)  # tuning is exact

    rep_s = static.simulate_timing()
    rep_f = feedback.simulate_timing()
    rep_t = sim.report
    report = tuned.tuning
    return {
        "name": f"autotune.{topo_name}.b{num_buckets}.skew{skew}",
        "topology": topo_name,
        "num_buckets": num_buckets,
        "skew": skew,
        "tune_us": round(tune_us, 1),
        # the three-way headline: static ECMP vs feedback-routed vs tuned
        "sim_time_us": round(rep_t.time_s * 1e6, 3),
        "sim_time_us_feedback": round(rep_f.time_s * 1e6, 3),
        "sim_time_us_static": round(rep_s.time_s * 1e6, 3),
        "makespan_ticks": rep_t.makespan_ticks,
        "makespan_ticks_feedback": rep_f.makespan_ticks,
        "makespan_ticks_static": rep_s.makespan_ticks,
        "queue_delay_ticks": rep_t.queue_delay_ticks,
        "wire_bytes": round(rep_t.wire_bytes, 1),
        "improvement_pct_vs_feedback": round(report.improvement_pct, 2),
        "actions_evaluated": len(report.actions),
        # candidate-cache effectiveness (mutation-only keys): fat-tree
        # cells used to sit at 0% because route churn leaked into the key
        "cache_hits": report.cache_hits,
        "cache_misses": report.cache_misses,
        "cache_hit_rate": round(report.cache_hit_rate, 3),
        "accepted_by_kind": report.accepted_by_kind(),
        "tuning": report.to_dict(),
    }


def run() -> list[tuple[str, float, str]]:
    records = [_case(*case) for case in CASES]
    write_bench(OUT_PATH, records)

    rows = []
    for r in records:
        accepted = ", ".join(
            f"{k}×{n}" for k, n in sorted(r["accepted_by_kind"].items())
        ) or "none"
        rows.append((
            r["name"],
            r["sim_time_us"],
            f"static={r['makespan_ticks_static']}t feedback={r['makespan_ticks_feedback']}t "
            f"tuned={r['makespan_ticks']}t ({r['improvement_pct_vs_feedback']:+.1f}% vs "
            f"feedback) accepted=[{accepted}]",
        ))
    rows.append(("autotune.artifact", 0.0, f"wrote {os.path.basename(OUT_PATH)}"))
    return rows


def print_summary(path: str = OUT_PATH) -> None:
    """Accepted-action summary of a BENCH_autotune.json (CI job log)."""
    with open(path) as f:
        _, records = strip_provenance(json.load(f))
    for r in records:
        print(f"{r['name']}: feedback={r['makespan_ticks_feedback']}t "
              f"tuned={r['makespan_ticks']}t ({r['improvement_pct_vs_feedback']:+.1f}%)")
        accepted = [a for a in r["tuning"]["actions"] if a["accepted"]]
        if not accepted:
            print("  no action accepted (feedback plan already at a local optimum)")
        for a in accepted:
            print(f"  round {a['round']} [{a['kind']}] {a['detail']}: "
                  f"{a['time_s_before'] * 1e6:.1f}us -> {a['time_s_after'] * 1e6:.1f}us")


if __name__ == "__main__":
    if "--summary" in sys.argv:
        print_summary()
    else:
        for row, us, derived in run():
            print(f"{row},{us:.2f},{derived}")
