"""Benchmark-regression gate for the CI bench-smoke job.

Compares a freshly generated BENCH_*.json against the committed baseline
and fails (exit 1) when any *simulated* metric regresses beyond the
tolerance. Only deterministic simulator outputs are compared — streamed
makespan, modelled time, queueing, wire bytes — never wall-clock fields
like ``compile_us``/``simulate_us``, which vary with the runner. All
gated metrics are lower-is-better.

Records are matched by their identity fields (name, topology,
num_buckets, skew — whichever are present). Coverage mismatches fail in
*both* directions: a baseline record missing from the current run is
silent coverage loss, and a current record missing from the baseline is
an ungated cell masquerading as green — regenerate and commit the
baseline, or pass ``--allow-new`` for the one run that introduces it.

    python benchmarks/check_regression.py \
        --baseline /tmp/bench-baseline/BENCH_shuffle.json \
        --current BENCH_shuffle.json --tolerance 0.10
"""
from __future__ import annotations

import argparse
import json
import sys

try:
    from benchmarks._provenance import strip_provenance
except ImportError:  # run as a script: benchmarks/ itself is sys.path[0]
    from _provenance import strip_provenance

# lower-is-better simulated metrics the gate compares (exact-name match)
GATED_METRICS = (
    "sim_time_us",
    "sim_time_us_static",
    "sim_time_us_feedback",
    "sim_time_best_us",
    "sim_time_flat_us",
    "makespan_ticks",
    "makespan_ticks_static",
    "makespan_ticks_feedback",
    "makespan_ticks_scheduled",
    "makespan_ticks_unscheduled",
    "makespan_ticks_monitored",
    "makespan_ticks_threshold_only",
    "detection_latency_ticks_mean",
    "detection_latency_ticks_max",
    "queue_delay_ticks",
    "queue_delay_ticks_static",
    "weighted_flow_ticks",
    "wire_bytes",
)
# higher-is-better metrics: the vectorized simulator's throughput edge.
# ``speedup_vs_event`` is a same-machine wall-clock *ratio* (vectorized
# vs event engine on identical inputs), so unlike the absolute
# ``packets_per_sec_*`` fields — reported but deliberately ungated, they
# track runner speed — it is comparable across CI machines. A shrinking
# ratio means the vectorized core itself got slower.
HIGHER_IS_BETTER = ("speedup_vs_event",)
# fields that identify a record across runs (all that are present)
IDENTITY = ("name", "topology", "num_buckets", "skew")
ABS_EPSILON = 2.0  # ignore sub-tick jitter on tiny integer metrics


def record_key(rec: dict) -> tuple:
    return tuple((k, rec[k]) for k in IDENTITY if k in rec)


def cell_label(key: tuple) -> str:
    """Human-readable cell identity (``name=... num_buckets=...``) for
    failure messages — names the exact record the regression is in."""
    return " ".join(f"{k}={v}" for k, v in key) or "<record>"


def check(
    baseline: list[dict],
    current: list[dict],
    tolerance: float,
    *,
    allow_new: bool = False,
    higher_tolerance: float | None = None,
) -> list[str]:
    """Compare ``current`` records against ``baseline``; returns the list
    of failure messages (empty = gate passes).

    A current record with no baseline counterpart is an error unless
    ``allow_new`` — a cell the gate silently skips would read as green
    while measuring nothing.

    ``higher_tolerance`` (default: ``tolerance``) applies to the
    HIGHER_IS_BETTER metrics only — wall-clock *ratios* are noisier than
    deterministic tick counts, so a caller can keep tick metrics tight
    while giving the speedup gate slack on shared runners."""
    if higher_tolerance is None:
        higher_tolerance = tolerance
    cur_by_key = {record_key(r): r for r in current}
    errors: list[str] = []
    compared = 0
    base_keys = {record_key(b) for b in baseline}
    for rec in current:
        key = record_key(rec)
        if key in base_keys:
            continue
        if allow_new:
            print(f"note: new cell [{cell_label(key)}] has no baseline yet (--allow-new)")
            continue
        errors.append(
            f"cell [{cell_label(key)}]: present in current run but missing from "
            "the baseline — this cell is NOT gated; regenerate and commit the "
            "baseline BENCH json (or pass --allow-new to accept it this run)"
        )
    for base in baseline:
        key = record_key(base)
        label = cell_label(key)
        cur = cur_by_key.get(key)
        if cur is None:
            errors.append(f"cell [{label}]: baseline record missing from current run")
            continue
        for metric in GATED_METRICS:
            if metric not in base or metric not in cur:
                continue
            b, c = float(base[metric]), float(cur[metric])
            compared += 1
            if c > b * (1.0 + tolerance) + ABS_EPSILON:
                errors.append(
                    f"cell [{label}] metric {metric}: regressed {b:g} -> {c:g} "
                    f"(+{100.0 * (c - b) / max(b, 1e-12):.1f}%, tolerance "
                    f"{100.0 * tolerance:.0f}%)"
                )
        for metric in HIGHER_IS_BETTER:
            if metric not in base or metric not in cur:
                continue
            b, c = float(base[metric]), float(cur[metric])
            compared += 1
            if c < b * (1.0 - higher_tolerance):
                errors.append(
                    f"cell [{label}] metric {metric}: regressed {b:g} -> {c:g} "
                    f"({100.0 * (c - b) / max(b, 1e-12):.1f}%, tolerance "
                    f"-{100.0 * higher_tolerance:.0f}%)"
                )
    if compared == 0:
        errors.append("no comparable metrics found between baseline and current")
    return errors


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True, help="committed BENCH json")
    ap.add_argument("--current", required=True, help="freshly generated BENCH json")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed relative regression (default 0.10 = 10%%)")
    ap.add_argument("--allow-new", action="store_true",
                    help="accept current cells that have no baseline yet "
                         "(default: fail — an ungated cell reads as green)")
    ap.add_argument("--higher-tolerance", type=float, default=None,
                    help="separate tolerance for higher-is-better "
                         "(wall-clock ratio) metrics; default: --tolerance")
    args = ap.parse_args(argv)
    # provenance records (who/when/where the numbers were generated) are
    # metadata, never gated — strip them before comparing
    with open(args.baseline) as f:
        _, baseline = strip_provenance(json.load(f))
    with open(args.current) as f:
        _, current = strip_provenance(json.load(f))
    errors = check(baseline, current, args.tolerance, allow_new=args.allow_new,
                   higher_tolerance=args.higher_tolerance)
    if errors:
        print(f"FAIL: {len(errors)} regression(s) beyond {100 * args.tolerance:.0f}%:")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"OK: {len(baseline)} baseline record(s) within {100 * args.tolerance:.0f}%")
    return 0


if __name__ == "__main__":
    sys.exit(main())
