"""Run provenance for the BENCH_*.json artifacts.

Every bench writer stamps its artifact with one leading
``{"provenance": {...}}`` record — when/where the numbers came from
(timestamp, host, python/numpy/jax versions, git sha) — so a perf
trajectory read months later is interpretable: "the makespan moved here"
can be told apart from "the runner changed here".

``check_regression.py`` (and every other artifact consumer) strips the
block with ``strip_provenance`` before comparing records; provenance is
metadata about a run, never a gated metric.
"""
from __future__ import annotations

import datetime
import json
import platform
import socket
import subprocess
import sys


def provenance() -> dict:
    """Environment fingerprint of this bench run (all fields best-effort:
    a missing git binary or an un-importable jax must never fail a bench)."""
    info: dict = {
        "timestamp_utc": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "host": socket.gethostname(),
        "platform": platform.platform(),
        "python": platform.python_version(),
    }
    try:
        import numpy

        info["numpy"] = numpy.__version__
    except Exception:
        pass
    try:
        import jax

        info["jax"] = jax.__version__
    except Exception:
        pass
    try:
        info["git_sha"] = (
            subprocess.run(
                ["git", "rev-parse", "--short", "HEAD"],
                capture_output=True, text=True, timeout=5, check=True,
            ).stdout.strip()
        )
    except Exception:
        pass
    return info


def write_bench(path: str, records: list) -> None:
    """Write a BENCH json: one provenance record, then the data records."""
    with open(path, "w") as f:
        json.dump([{"provenance": provenance()}, *records], f, indent=2)


def strip_provenance(records: list) -> tuple[dict | None, list]:
    """Split a loaded BENCH json into (provenance | None, data records).
    Tolerates artifacts written before provenance existed (no block) and
    a block at any position (hand-edited files)."""
    prov = None
    data = []
    for rec in records:
        if isinstance(rec, dict) and set(rec) == {"provenance"}:
            prov = rec["provenance"]
        else:
            data.append(rec)
    return prov, data
