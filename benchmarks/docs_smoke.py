"""Docs gate for CI's docs job: links resolve, snippets run.

Two checks over the committed documentation:

1. **link check** — every relative markdown link in ``docs/*.md`` and
   ``README.md`` must point at an existing file or directory (external
   ``http(s)://`` links and pure ``#anchor`` links are skipped; a
   ``path#anchor`` suffix is stripped before resolving).
2. **snippet smoke** — every ```` ```python ```` fenced block in
   ``docs/p4mr.md`` and ``docs/telemetry.md`` is executed top-to-bottom
   in one shared namespace per document, so the API reference cannot
   drift from the actual API. Blocks are written to be sequential:
   later blocks use names bound by earlier ones.

    PYTHONPATH=src:. python benchmarks/docs_smoke.py
"""
from __future__ import annotations

import os
import re
import sys

# the p4mr.md backend snippet runs the jax backend on a host-device mesh
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# [text](target) — target without spaces or closing paren; matches
# images too (the leading ! is irrelevant to resolution)
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)


def _doc_files() -> list[str]:
    docs_dir = os.path.join(REPO, "docs")
    files = [os.path.join(docs_dir, f) for f in sorted(os.listdir(docs_dir))
             if f.endswith(".md")]
    files.append(os.path.join(REPO, "README.md"))
    return files


def check_links() -> list[str]:
    """Every relative link target in the docs must exist on disk."""
    errors = []
    for path in _doc_files():
        with open(path) as f:
            text = f.read()
        rel_dir = os.path.dirname(path)
        for m in _LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            target = target.split("#", 1)[0]
            resolved = os.path.normpath(os.path.join(rel_dir, target))
            if not os.path.exists(resolved):
                errors.append(
                    f"{os.path.relpath(path, REPO)}: broken link "
                    f"{m.group(1)!r} (resolved to {os.path.relpath(resolved, REPO)})"
                )
    return errors


def run_snippets(doc: str = "docs/p4mr.md") -> int:
    """Exec every python fence of ``doc`` in one namespace; returns the
    number of blocks run. Raises (with the block's position) on failure."""
    path = os.path.join(REPO, doc)
    with open(path) as f:
        text = f.read()
    ns: dict = {}
    blocks = list(_FENCE.finditer(text))
    for i, m in enumerate(blocks, 1):
        code = m.group(1)
        line = text[: m.start()].count("\n") + 2  # first line inside the fence
        try:
            exec(compile(code, f"{doc}:block{i}", "exec"), ns)
        except Exception as e:
            raise SystemExit(
                f"FAIL: {doc} block {i} (line {line}) raised "
                f"{type(e).__name__}: {e}"
            ) from e
        print(f"ok: {doc} block {i} (line {line})")
    return len(blocks)


def main() -> int:
    errors = check_links()
    if errors:
        print(f"FAIL: {len(errors)} broken doc link(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    n_files = len(_doc_files())
    print(f"ok: links resolve across {n_files} markdown file(s)")
    for doc in ("docs/p4mr.md", "docs/telemetry.md", "docs/verify.md"):
        n = run_snippets(doc)
        print(f"OK: {n} snippet block(s) from {doc} ran clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
