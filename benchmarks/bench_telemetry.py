"""Streaming-telemetry benchmark: detection latency, overhead, recovery.

Three cells on the k=4 fat-tree, writing BENCH_telemetry.json (gated by
CI's bench-smoke regression check):

* ``telemetry.fat_tree_k4.overhead_off`` — the zero-overhead-when-off
  contract. The same heavy plan is simulated twice on the vectorized
  engine: plain (telemetry off, no observers — the default fast path)
  and observed (a window stream + detector suite + SLO monitor riding
  the run). ``speedup_vs_event`` here is the wall ratio observed/plain
  on identical inputs: both sides move together under runner noise, so
  a *shrinking* ratio means the off path itself grew overhead — exactly
  what the higher-is-better gate catches. Makespans must be identical
  (observers are read-only).
* ``telemetry.fat_tree_k4.bursty_detect`` — a bursty tenant landing on
  a loaded fabric mid-run; the detector suite watches the merged run's
  windows live. Reports events found and per-event detection latency
  (detect − onset, in ticks — deterministic, gated).
* ``telemetry.fat_tree_k4.bursty_recovery`` — the loop closed: the same
  submission stream scheduled with the streaming monitor on vs off
  (``Scheduler(monitor=...)``). The threshold-only baseline retunes only
  the burst job (drift 129 ≫ 0.75) and misses the heavy job whose
  end-of-run drift dilutes to ~0.73; the monitored path retunes it off
  the queue-growth onset and recovers makespan.

    PYTHONPATH=src:. python benchmarks/run.py telemetry
    PYTHONPATH=src:. python benchmarks/bench_telemetry.py
"""
from __future__ import annotations

import os
import time

from repro import p4mr
from repro.compiler.cost import CostModel
from repro.core import topology
from repro.telemetry.anomaly import default_detectors
from repro.telemetry.slo import SloMonitor, SloTarget
from repro.telemetry.stream import WindowRecorder

from benchmarks._provenance import write_bench

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                        "BENCH_telemetry.json")

# sample every 8 ticks, fold into 32-tick windows: 4 samples per window
_COST = CostModel(sim_telemetry_interval=8.0, sim_telemetry_window=32.0)


def _wordcount_tenant(name: str, hosts: list[str], sink: str, vocab: int) -> p4mr.Job:
    job = p4mr.job(name)
    keyed = [
        job.store(f"s{i}", host=h, items=vocab).key_by(4)
        for i, h in enumerate(hosts)
    ]
    keyed[0].reduce("SUM", *keyed[1:], label="R").collect(sink, label="OUT")
    return job


def _heavy() -> p4mr.Job:
    return _wordcount_tenant("heavy", [f"h{i}" for i in range(8)], "h15", 512)


def _burst() -> p4mr.Job:
    return _wordcount_tenant("burst", [f"h{i}" for i in range(8, 12)], "h14", 64)


def _overhead_case() -> dict:
    """Plain vs observed simulation of the same plan: the off path must
    stay a fast path. Best-of-3 walls; ratio gated higher-is-better."""
    sess = p4mr.Session(topology.fat_tree_topology(4), cost_model=_COST)
    pl = sess.compile(_heavy())
    spec = pl.flow_spec()  # prebuild so both sides time the engine alone
    from repro.compiler.simulator import simulate_timing

    def wall(observers):
        best = float("inf")
        mk = None
        for _ in range(3):
            t0 = time.perf_counter()
            rep = simulate_timing(pl.program, pl.routes, _COST,
                                  engine="vectorized", spec=spec,
                                  observers=observers)
            best = min(best, (time.perf_counter() - t0) * 1e6)
            mk = rep.makespan_ticks
        return best, mk

    plain_us, mk_plain = wall(None)
    observed = [WindowRecorder(), default_detectors(),
                SloMonitor([SloTarget("heavy", deadline_ticks=2000.0,
                                      sinks=("OUT",))])]
    observed_us, mk_observed = wall(observed)
    assert mk_plain == mk_observed, "observers must not perturb the schedule"
    return {
        "name": "telemetry.fat_tree_k4.overhead_off",
        "topology": "fat_tree_k4",
        "simulate_plain_us": round(plain_us, 2),
        "simulate_observed_us": round(observed_us, 2),
        # observed/plain wall ratio — shrinks if the OFF path gains
        # overhead; rides the existing higher-is-better speedup gate
        "speedup_vs_event": round(observed_us / max(plain_us, 1e-9), 3),
        "makespan_ticks": mk_plain,
    }


def _bursty_pair(monitor: bool):
    sess = p4mr.Session(topology.fat_tree_topology(4), cost_model=_COST)
    sched = p4mr.Scheduler(sess, reroute_rounds=0, retune_rounds=2,
                           monitor=monitor)
    sched.submit(_heavy(), name="heavy", deadline=1500)
    sched.submit(_burst(), name="burst", at=200)
    return sched.run()


def _detect_and_recovery_cases() -> list[dict]:
    t0 = time.perf_counter()
    threshold = _bursty_pair(monitor=False)
    threshold_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    monitored = _bursty_pair(monitor=True)
    monitored_us = (time.perf_counter() - t0) * 1e6

    lat = [e.detection_latency_ticks for e in monitored.anomalies]
    assert monitored.anomalies, "bursty cell must trip the detector suite"
    assert monitored.makespan_ticks <= threshold.makespan_ticks, (
        "monitored schedule lost to the threshold-only baseline"
    )
    detect = {
        "name": "telemetry.fat_tree_k4.bursty_detect",
        "topology": "fat_tree_k4",
        "anomaly_events": len(monitored.anomalies),
        "anomaly_kinds": sorted({e.kind for e in monitored.anomalies}),
        "first_onset_tick": min(e.onset_tick for e in monitored.anomalies),
        "detection_latency_ticks_mean": round(sum(lat) / len(lat), 3),
        "detection_latency_ticks_max": max(lat),
        "slo_violations": sum(
            1 for st in monitored.slo_statuses.values() if st.violated
        ),
    }
    recovery = {
        "name": "telemetry.fat_tree_k4.bursty_recovery",
        "topology": "fat_tree_k4",
        "schedule_us_monitored": round(monitored_us, 2),
        "schedule_us_threshold": round(threshold_us, 2),
        "makespan_ticks_monitored": monitored.makespan_ticks,
        "makespan_ticks_threshold_only": threshold.makespan_ticks,
        "recovered_vs_threshold_ticks": (
            threshold.makespan_ticks - monitored.makespan_ticks
        ),
        "hot_swaps_monitored": len(monitored.hot_swaps),
        "hot_swaps_threshold_only": len(threshold.hot_swaps),
        "anomaly_triggered_swaps": sum(
            1 for s in monitored.hot_swaps if s.trigger == "anomaly"
        ),
    }
    return [detect, recovery]


def run() -> list[tuple[str, float, str]]:
    records = [_overhead_case(), *_detect_and_recovery_cases()]
    write_bench(OUT_PATH, records)
    rows = []
    for r in records:
        if r["name"].endswith("overhead_off"):
            rows.append((
                f"telemetry.{r['name']}", r["simulate_plain_us"],
                f"observed/plain={r['speedup_vs_event']} "
                f"makespan={r['makespan_ticks']}t",
            ))
        elif r["name"].endswith("bursty_detect"):
            rows.append((
                f"telemetry.{r['name']}", 0.0,
                f"events={r['anomaly_events']} "
                f"latency_mean={r['detection_latency_ticks_mean']}t "
                f"latency_max={r['detection_latency_ticks_max']}t",
            ))
        else:
            rows.append((
                f"telemetry.{r['name']}", r["schedule_us_monitored"],
                f"monitored={r['makespan_ticks_monitored']}t "
                f"threshold={r['makespan_ticks_threshold_only']}t "
                f"recovered={r['recovered_vs_threshold_ticks']}t",
            ))
    rows.append(("telemetry.artifact", 0.0, f"wrote {os.path.basename(OUT_PATH)}"))
    return rows


if __name__ == "__main__":
    for row, us, derived in run():
        print(f"{row},{us:.2f},{derived}")
