"""Per-kernel timing: jitted oracle µs/call on CPU + Pallas(interpret)
correctness spot-check. Wall-clock on TPU is out of scope (no hardware);
the structural VMEM/MXU analysis lives in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, iters=20) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> list[tuple[str, float, str]]:
    rows = []
    rs = np.random.RandomState(0)

    v = jnp.asarray(rs.randn(16384, 64).astype(np.float32))
    ids = jnp.asarray(rs.randint(0, 64, 16384).astype(np.int32))
    sr = jax.jit(lambda a, b: ref.segment_reduce(a, b, 64))
    rows.append(("kernels.segment_reduce.ref", _time(sr, v, ids),
                 "n=16384 d=64 nseg=64 (oracle)"))
    got = ops.segment_reduce(v, ids, 64, interpret=True)
    err = float(jnp.max(jnp.abs(got - sr(v, ids))))
    rows.append(("kernels.segment_reduce.allclose", 0.0, f"max_err={err:.2e}"))

    t = jnp.asarray(rs.randint(0, 100000, 65536).astype(np.int32))
    hp = jax.jit(lambda a: ref.hash_partition(a, 16))
    rows.append(("kernels.hash_partition.ref", _time(hp, t), "n=65536 buckets=16"))

    acc = jnp.asarray(rs.randn(1 << 20).astype(np.float32))
    wire = jnp.asarray(rs.randn(1 << 20).astype(np.float32)).astype(jnp.bfloat16)
    rf = jax.jit(ref.ring_fused_step)
    rows.append(("kernels.ring_fused_step.ref", _time(rf, acc, wire), "n=1M"))

    q = jnp.asarray(rs.randn(1, 4, 1024, 64).astype(np.float32))
    fa = jax.jit(lambda a: ref.flash_attention(a, a, a, causal=True))
    rows.append(("kernels.flash_attention.ref", _time(fa, q, iters=5),
                 "b1 h4 s1024 d64 causal"))
    return rows
